//! §Perf — serving-path throughput: micro-batched vs unbatched.
//!
//! Drives the leader/worker server with a pure `mm_pu128` stream (the
//! acceptance workload) and a mixed stream, once with batching disabled
//! (`max_batch = 1` — every job is its own dispatch, the old serving
//! shape) and once with micro-batching on. The batched interpreter path
//! stacks compatible jobs along a leading batch dimension and runs the
//! cache-blocked kernels, so the same workers clear more jobs per
//! second; the speedup line below is the number the ISSUE acceptance
//! criterion reads (>= 1.5x on the pure-mm stream).
//!
//! A final open-loop section offers Poisson arrivals just above the
//! measured batched capacity and reports shed rate plus the
//! queue-vs-exec latency split — the backpressure story, measured.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::time::{Duration, Instant};

use ea4rca::coordinator::router::{ClusterConfig, Router};
use ea4rca::coordinator::server::{serve_open_loop, JobResult, Server, ServerConfig};
use ea4rca::runtime::{BackendKind, Manifest, Tensor};
use ea4rca::util::bench::BenchRecorder;
use ea4rca::util::stats::summarize;
use ea4rca::util::table::{fmt_f, Table};
use ea4rca::workload::{generate_stream, open_loop_stream, Mix, TaskKind};

const WORKERS: usize = 4;
const WARMUP: [&str; 4] = ["mm_pu128", "fft1024", "filter2d_pu8", "mmt_cascade8"];

struct RunStats {
    jobs_per_sec: f64,
    mean_batch: f64,
    queue_ms_p95: f64,
    exec_ms_mean: f64,
}

/// Closed-loop: submit the whole stream, wait for every reply.
fn run_closed(mix: &Mix, n_jobs: usize, seed: u64, max_batch: usize) -> RunStats {
    let config = ServerConfig {
        n_workers: WORKERS,
        max_batch,
        max_linger: Duration::from_micros(500),
        queue_cap: 512,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &WARMUP,
    )
    .expect("server start");
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(mix, n_jobs, seed)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(jobs.len());
    for (artifact, inputs) in jobs {
        pending.push(server.submit(&artifact, inputs).expect("submit"));
    }
    let results: Vec<JobResult> =
        pending.into_iter().map(|p| p.wait().expect("reply")).collect();
    let wall = t0.elapsed().as_secs_f64();
    assert!(results.iter().all(|r| r.outputs.is_ok()), "serving errors");
    let report = server.shutdown().expect("shutdown");
    assert_eq!(report.completed_jobs(), n_jobs as u64, "jobs lost or duplicated");
    let queue = summarize(&results.iter().map(|r| r.queue_secs).collect::<Vec<_>>());
    let exec = summarize(&results.iter().map(|r| r.exec_secs).collect::<Vec<_>>());
    let total_batches: u64 = report.batches;
    RunStats {
        jobs_per_sec: n_jobs as f64 / wall,
        mean_batch: n_jobs as f64 / total_batches.max(1) as f64,
        queue_ms_p95: queue.p95 * 1e3,
        exec_ms_mean: exec.mean * 1e3,
    }
}

/// Closed-loop through the shard cluster: same total worker count,
/// split across `shards` shards of `workers_each` workers.
fn run_cluster(mix: &Mix, n_jobs: usize, seed: u64, shards: usize, workers_each: usize) -> f64 {
    let cluster = ClusterConfig {
        shards,
        shard: ServerConfig {
            n_workers: workers_each,
            max_batch: 8,
            max_linger: Duration::from_micros(500),
            queue_cap: 512,
        },
    };
    let router = Router::start(BackendKind::Interp, cluster, Manifest::default_dir(), &WARMUP)
        .expect("router start");
    let jobs: Vec<(String, Vec<Tensor>)> = generate_stream(mix, n_jobs, seed)
        .into_iter()
        .map(|(k, i)| (k.artifact().to_string(), i))
        .collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(jobs.len());
    for (artifact, inputs) in jobs {
        pending.push(router.submit(&artifact, inputs).expect("submit"));
    }
    for p in pending {
        assert!(p.wait().expect("reply").outputs.is_ok(), "serving errors");
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = router.shutdown().expect("shutdown");
    assert_eq!(report.completed_jobs(), n_jobs as u64, "jobs lost or duplicated");
    n_jobs as f64 / wall
}

fn main() {
    let n_jobs = 256;
    let mut rec = BenchRecorder::new("serve_throughput");
    rec.note("workers", WORKERS)
        .note("n_jobs", n_jobs)
        .note("backend", "interp")
        .note("workload", "closed loop batched-vs-unbatched; open loop at 1.2x capacity; shard shapes");

    let mut t = Table::new(
        "serving throughput: micro-batched vs unbatched (interp, 4 workers)",
        &["stream", "mode", "jobs/s", "mean batch", "exec mean (ms)", "queue p95 (ms)"],
    );
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for (key, label, mix) in [
        ("pure_mm", "pure mm_pu128".to_string(), Mix::single(TaskKind::MmBlock)),
        // fft rides the prepared-artifact cache: the plan (bit-reversal
        // + twiddles) is built once per worker, shared by single-job
        // and batched dispatches alike
        ("pure_fft", "pure fft1024".to_string(), Mix::single(TaskKind::Fft1024)),
        ("mm_heavy_mixed", "mm-heavy mixed".to_string(), Mix::mm_heavy()),
    ] {
        let unbatched = run_closed(&mix, n_jobs, 17, 1);
        let batched = run_closed(&mix, n_jobs, 17, 8);
        for ((mode, s), mode_key) in [("unbatched", &unbatched), ("batched x8", &batched)]
            .into_iter()
            .zip(["unbatched", "batched"])
        {
            t.row(&[
                label.clone(),
                mode.to_string(),
                fmt_f(s.jobs_per_sec, 0),
                fmt_f(s.mean_batch, 2),
                fmt_f(s.exec_ms_mean, 3),
                fmt_f(s.queue_ms_p95, 2),
            ]);
            rec.metric(&format!("{key}.{mode_key}.jobs_per_sec"), s.jobs_per_sec, "jobs/s")
                .metric(&format!("{key}.{mode_key}.mean_batch"), s.mean_batch, "jobs/batch")
                .metric(&format!("{key}.{mode_key}.exec_ms_mean"), s.exec_ms_mean, "ms")
                .metric(&format!("{key}.{mode_key}.queue_ms_p95"), s.queue_ms_p95, "ms");
        }
        rec.metric(
            &format!("{key}.batched_speedup"),
            batched.jobs_per_sec / unbatched.jobs_per_sec,
            "x",
        );
        speedups.push((label, batched.jobs_per_sec / unbatched.jobs_per_sec));
    }
    t.print();
    for (label, s) in &speedups {
        println!("micro-batched speedup on {label}: {s:.2}x");
    }
    let mm_speedup = speedups[0].1;
    println!(
        "acceptance (pure mm_pu128 >= 1.5x): {}",
        if mm_speedup >= 1.5 { "PASS" } else { "MISS" }
    );

    // ---- open loop: offered load just above batched capacity ----
    let capacity = run_closed(&Mix::single(TaskKind::MmBlock), n_jobs, 19, 8).jobs_per_sec;
    let rate = capacity * 1.2;
    let config = ServerConfig {
        n_workers: WORKERS,
        max_batch: 8,
        max_linger: Duration::from_micros(500),
        queue_cap: 64,
    };
    let server = Server::start_with_config(
        BackendKind::Interp,
        config,
        Manifest::default_dir(),
        &WARMUP,
    )
    .expect("server start");
    let arrivals = open_loop_stream(&Mix::single(TaskKind::MmBlock), n_jobs, 23, rate)
        .into_iter()
        .map(|a| (a.at_secs, a.kind.artifact(), a.inputs));
    let t0 = Instant::now();
    let (results, shed) = serve_open_loop(&server, arrivals).expect("open loop");
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown().expect("shutdown");
    let served = results.len();
    println!(
        "\nopen loop at {rate:.0} jobs/s offered (1.2x capacity): served {served}/{n_jobs}, \
         shed {shed}, {:.0} jobs/s goodput",
        served as f64 / wall
    );
    rec.metric("open_loop.offered_rate", rate, "jobs/s")
        .metric("open_loop.goodput", served as f64 / wall, "jobs/s")
        .metric("open_loop.shed", shed as f64, "jobs");
    if !results.is_empty() {
        let queue = summarize(&results.iter().map(|r| r.queue_secs).collect::<Vec<_>>());
        let exec = summarize(&results.iter().map(|r| r.exec_secs).collect::<Vec<_>>());
        println!(
            "  queue ms: mean {:.2} p95 {:.2} | exec ms: mean {:.3} p95 {:.3}",
            queue.mean * 1e3,
            queue.p95 * 1e3,
            exec.mean * 1e3,
            exec.p95 * 1e3
        );
    }

    // ---- sharded: the same 4 workers as one array vs a cluster ----
    // Cost-weighted routing should keep a 2x2 or 4x1 cluster within
    // noise of the single 1x4 array on a mixed closed loop (same total
    // workers; the cluster buys isolation + drain, not raw speed here),
    // while per-shard caches and queues stop cross-artifact contention.
    let mut t = Table::new(
        "sharded serving: shards x workers, same 4 total workers (mixed stream)",
        &["cluster", "jobs/s", "vs 1x4"],
    );
    let shapes = [(1usize, 4usize), (2, 2), (4, 1)];
    let mut baseline = 0.0f64;
    for (shards, each) in shapes {
        let jps = run_cluster(&Mix::mm_heavy(), n_jobs, 29, shards, each);
        if shards == 1 {
            baseline = jps;
        }
        t.row(&[
            format!("{shards} x {each}"),
            fmt_f(jps, 0),
            format!("{:.2}x", jps / baseline.max(1e-9)),
        ]);
        rec.metric(&format!("cluster.{shards}x{each}.jobs_per_sec"), jps, "jobs/s");
    }
    t.print();
    rec.write();
}
