//! Table 6 — MM accelerator performance across task scales and PU
//! quantities. Regenerates all 12 rows and compares the headline cells
//! to the paper.
//!
//! Run: `cargo bench --bench table6_mm`

use ea4rca::apps::mm;
use ea4rca::report::{compare_line, perf_row, perf_table};
use ea4rca::sim::params::HwParams;

fn main() {
    let p = HwParams::vck5000();
    let mut t = perf_table("Table 6 — MM accelerator (Float)");
    let wall = std::time::Instant::now();
    for size in [768usize, 1536, 3072, 6144] {
        for (pus, label) in [(6, "6(100%)"), (3, "3(50%)"), (1, "1(17%)")] {
            let r = mm::run(&p, size, pus, false).expect("run");
            perf_row(&mut t, &format!("{size}^3"), label, &r, None);
        }
    }
    t.print();
    println!("(sweep simulated in {:.2} s wall-clock)\n", wall.elapsed().as_secs_f64());

    // paper anchors
    let r = mm::run(&p, 6144, 6, false).unwrap();
    println!("{}", compare_line("6144^3 6PU time (ms)", 135.59, r.time_secs * 1e3));
    println!("{}", compare_line("6144^3 6PU GOPS", 3421.02, r.gops));
    println!("{}", compare_line("6144^3 6PU GOPS/AIE", 8.90, r.gops_per_aie));
    println!("{}", compare_line("6144^3 6PU power (W)", 42.13, r.power_w));
    println!("{}", compare_line("6144^3 6PU GOPS/W", 81.20, r.gops_per_w));
    let r = mm::run(&p, 768, 6, false).unwrap();
    println!("{}", compare_line("768^3 6PU time (ms)", 0.44, r.time_secs * 1e3));
    let r = mm::run(&p, 768, 1, false).unwrap();
    println!("{}", compare_line("768^3 1PU time (ms)", 1.84, r.time_secs * 1e3));
}
