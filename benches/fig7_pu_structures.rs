//! Figure 7 / Table 4 — the PU structures of the four accelerators,
//! printed from the same configuration files the code generator
//! consumes, with the component-implementation matrix.
//!
//! Run: `cargo bench --bench fig7_pu_structures`

use ea4rca::api::Design;
use ea4rca::util::table::Table;

fn main() {
    println!("Figure 7 / Table 4 — PU designs of the four accelerators\n");
    let mut t = Table::new(
        "Component implementations (Table 4)",
        &["APP", "PST", "DAC", "CC", "DCC", "cores", "PLIO in", "PLIO out"],
    );
    for name in ["mm", "filter2d", "fft", "mmt"] {
        let design = Design::from_path(format!("configs/{name}.json"))
            .expect("run from the repo root");
        let cfg = design.config();
        for (i, pst) in cfg.pu.psts.iter().enumerate() {
            let dac = pst
                .dacs
                .iter()
                .map(|d| d.label())
                .collect::<Vec<_>>()
                .join(",");
            let dcc = pst
                .dccs
                .iter()
                .map(|d| d.mode.name().to_string())
                .collect::<Vec<_>>()
                .join(",");
            t.row(&[
                if i == 0 { cfg.name.clone() } else { String::new() },
                format!("#{}", i + 1),
                dac,
                pst.cc.to_string(),
                dcc,
                pst.cc.cores().to_string(),
                pst.in_plios().to_string(),
                pst.out_plios().to_string(),
            ]);
        }
    }
    t.print();

    println!("\ngenerated graph summaries (the Fig 7 wiring):");
    for name in ["mm", "filter2d", "fft", "mmt"] {
        let design = Design::from_path(format!("configs/{name}.json")).unwrap();
        let proj = design.generate().unwrap();
        let cascades = proj.graph_h.matches("connect<cascade>").count();
        let streams = proj.graph_h.matches("connect<stream>").count();
        println!(
            "  {:<9} {:>3} cores | {} cascade connect blocks | {} stream connects | x{} copies",
            design.name(),
            design.cores(),
            cascades,
            streams,
            design.copies()
        );
    }
}
