//! Table 5 — hardware resource utilisation of the four accelerators,
//! plus a placement check on the 8x50 AIE array.
//!
//! Run: `cargo bench --bench table5_resources`

use ea4rca::apps::table5_usage;
use ea4rca::sim::array::AieArray;
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::Table;

fn main() {
    let p = HwParams::vck5000();
    let mut t = Table::new(
        "Table 5 — hardware resource utilisation",
        &["Apps", "LUT", "FF", "BRAM", "URAM", "DSP", "AIE", "DU", "PU"],
    );
    for (app, du, pu) in [("MM", 1, 6), ("Filter2D", 11, 44), ("FFT", 8, 8), ("MM-T", 50, 50)] {
        let u = table5_usage(app).expect("known app");
        u.check(&p).expect("design must fit the card");
        let mut row = vec![app.to_string()];
        row.extend(u.table5_row(&p));
        row.push(du.to_string());
        row.push(pu.to_string());
        t.row(&row);
    }
    t.print();

    // Placement: the array must actually accommodate each design.
    println!("\nplacement check on the 8x50 array:");
    for (app, pus, cores_per_pu) in
        [("MM", 6, 64), ("Filter2D", 44, 8), ("FFT", 8, 10), ("MM-T", 50, 8)]
    {
        let mut arr = AieArray::new(&p);
        // the placer handles non-tiling PUs directly (the FFT PU's 10
        // cores land as 1 full column + a 2-core trailing column)
        let mut placed = 0;
        for _ in 0..pus {
            let pl = arr.place(cores_per_pu).unwrap();
            placed += pl.cores();
        }
        println!(
            "  {app:<9} {placed:>3} cores placed, array utilisation {:.0}%",
            arr.utilization() * 100.0
        );
    }
}
