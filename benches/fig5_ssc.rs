//! Figure 5 — the four SSC service modes (PSD / SHD / PHD / THR) and
//! their service timing over 4 PUs, plus the SHD-vs-PHD efficiency
//! comparison the paper draws (slow PUs delay SHD, PHD needs buffer).
//!
//! Run: `cargo bench --bench fig5_ssc`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::engine::data::ssc::SscMode;
use ea4rca::sim::params::HwParams;

fn main() {
    let p = HwParams::vck5000();
    println!("Figure 5 — SSC service modes, 4 PUs, 1 us wire time per PU\n");
    let per = 1e-6;
    for mode in [SscMode::Psd, SscMode::Shd, SscMode::Phd] {
        println!("{} :", mode.name());
        for pu in 0..4 {
            let off = mode.service_start_offset(pu, per);
            let start = (off * 1e6 * 10.0) as usize;
            let width = (per * 1e6 * 10.0) as usize;
            let mut row = vec![' '; 60];
            for c in row.iter_mut().skip(start).take(width) {
                *c = '=';
            }
            println!("  PU{pu} |{}|", row.iter().collect::<String>());
        }
        println!(
            "  group service {:.1} us, staging {} B per KB of subproblem\n",
            mode.group_service_secs(4, per) * 1e6,
            mode.staging_bytes(4, 1024)
        );
    }
    println!("THR : single PU, direct wire (group of 1)\n");

    // end-to-end effect on the MM design: SHD vs PHD over 64 iterations
    let engine = SimEngine::new(p.clone());
    let mut results = Vec::new();
    for mode in [SscMode::Phd, SscMode::Shd] {
        let mut du = mm::mm_du(4, 6);
        du.ssc_send = mode;
        let g = GroupSpec {
            name: format!("mm-{}", mode.name()),
            du,
            pu: mm::mm_pu(),
            engine_iters: 64,
mode: ExecMode::Regular,
        };
        let r = engine.run(&[g]);
        println!(
            "MM 4-PU group, 64 iterations, SSC={}: makespan {:.1} us, duty {:.2}",
            mode.name(),
            r.makespan_secs * 1e6,
            r.compute_duty
        );
        results.push(r.makespan_secs);
    }
    println!(
        "\nSHD is {:.2}x slower than PHD on this design — the Fig 5 trade \
         (PHD buys the difference with URAM staging).",
        results[1] / results[0]
    );
    assert!(results[1] > results[0]);
}
