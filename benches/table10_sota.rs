//! Table 10 — performance and energy-efficiency comparison between the
//! EA4RCA accelerators (measured on our substrate) and the published
//! SOTA baselines (CHARM, CCC2023, Vitis), with the paper's speed-up
//! and efficiency-up ratios recomputed.
//!
//! Run: `cargo bench --bench table10_sota`

use ea4rca::apps::{fft, filter2d, mm, mmt};
use ea4rca::baselines;
use ea4rca::report::compare_line;
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let mut t = Table::new(
        "Table 10 — EA4RCA vs SOTA",
        &["Apps", "Design", "Problem", "DType", "Tasks/sec", "GOPS",
          "Efficiency", "SpeedUp", "EffUp"],
    );

    // ---- MM vs CHARM ----
    let charm = baselines::charm::row();
    let r = mm::run(&p, 6144, 6, false).unwrap();
    t.row(&["MM".into(), charm.design.into(), "N/A".into(), "Float".into(),
            "N/A".into(), fmt_f(charm.gops.unwrap(), 2),
            format!("{} GOPS/W", fmt_f(charm.efficiency.unwrap(), 2)),
            "1.00x".into(), "1.00x".into()]);
    let mm_speed = r.gops / charm.gops.unwrap();
    let mm_eff = r.gops_per_w / charm.efficiency.unwrap();
    t.row(&["MM".into(), "EA4RCA".into(), "6144".into(), "Float".into(),
            fmt_f(r.tasks_per_sec, 2), fmt_f(r.gops, 2),
            format!("{} GOPS/W", fmt_f(r.gops_per_w, 2)),
            format!("{:.2}x", mm_speed), format!("{:.2}x", mm_eff)]);

    // ---- Filter2D vs CCC2023 champion ----
    let ccc = baselines::ccc2023::rows();
    for b in ccc.iter().filter(|b| b.app == "Filter2D") {
        t.row(&["Filter2D".into(), b.design.into(), b.problem.into(), b.dtype.into(),
                fmt_f(b.tasks_per_sec.unwrap(), 2), fmt_f(b.gops.unwrap(), 2),
                format!("{} GOPS/W", fmt_f(b.efficiency.unwrap(), 2)),
                "1.00x".into(), "1.00x".into()]);
    }
    let mut f2d_ratios = Vec::new();
    for (h, w, label, base_gops, base_eff) in
        [(3480usize, 2160usize, "4K (5x5)", 39.22, 5.04), (7680, 4320, "8K (5x5)", 59.72, 7.68)]
    {
        let r = filter2d::run(&p, h, w, 44, false).unwrap();
        let speed = r.gops / base_gops;
        let eff = r.gops_per_w / base_eff;
        f2d_ratios.push((label, speed, eff));
        t.row(&["Filter2D".into(), "EA4RCA".into(), label.into(), "Int32".into(),
                fmt_f(r.tasks_per_sec, 2), fmt_f(r.gops, 2),
                format!("{} GOPS/W", fmt_f(r.gops_per_w, 2)),
                format!("{:.2}x", speed), format!("{:.2}x", eff)]);
    }

    // ---- FFT vs Vitis + CCC2023 ----
    let vitis = baselines::vitis::row();
    t.row(&["FFT".into(), vitis.design.into(), "1024".into(), "CInt16".into(),
            fmt_f(vitis.tasks_per_sec.unwrap(), 2), "N/A".into(), "N/A".into(),
            "1.00x".into(), "N/A".into()]);
    for b in ccc.iter().filter(|b| b.app == "FFT") {
        t.row(&["FFT".into(), b.design.into(), b.problem.into(), b.dtype.into(),
                fmt_f(b.tasks_per_sec.unwrap(), 2), "N/A".into(),
                format!("{} TPS/W", fmt_f(b.efficiency.unwrap(), 2)),
                "1.00x".into(), "1.00x".into()]);
    }
    let mut fft_ratios = Vec::new();
    for (n, base_tps, base_eff) in [
        (1024usize, 713_826.80, 26_396.37), // speed vs Vitis, eff vs CCC
        (4096, 135_685.21, 22_796.57),
        (8192, 106_382.97, 16_396.88),
    ] {
        let r = fft::run(&p, n, 8, 4096, false).unwrap().unwrap();
        let speed = r.tasks_per_sec / base_tps;
        let eff = r.tasks_per_sec_per_w / base_eff;
        fft_ratios.push((n, speed, eff));
        t.row(&["FFT".into(), "EA4RCA".into(), n.to_string(), "CInt16".into(),
                fmt_f(r.tasks_per_sec, 2), "N/A".into(),
                format!("{} TPS/W", fmt_f(r.tasks_per_sec_per_w, 2)),
                format!("{:.2}x", speed), format!("{:.2}x", eff)]);
    }

    // ---- MM-T vs CHARM ----
    let r = mmt::run(&p, 20_000, false).unwrap();
    let mmt_speed = r.gops / 3270.0;
    let mmt_eff = r.gops_per_w / 62.40;
    t.row(&["MM-T".into(), "CHARM[47]".into(), "N/A".into(), "Float".into(),
            "N/A".into(), "3270.00".into(), "62.40 GOPS/W".into(),
            "1.00x".into(), "1.00x".into()]);
    t.row(&["MM-T".into(), "EA4RCA".into(), "32".into(), "Float".into(),
            fmt_f(r.tasks_per_sec, 2), fmt_f(r.gops, 2),
            format!("{} GOPS/W", fmt_f(r.gops_per_w, 2)),
            format!("{:.2}x", mmt_speed), format!("{:.2}x", mmt_eff)]);
    t.print();

    // ---- ratio anchors vs the paper ----
    println!();
    println!("{}", compare_line("MM speedup vs CHARM", 1.05, mm_speed));
    println!("{}", compare_line("MM eff-up vs CHARM", 1.30, mm_eff));
    for ((label, s, e), (ps, pe)) in
        f2d_ratios.iter().zip([(22.19, 6.11), (16.55, 4.26)])
    {
        println!("{}", compare_line(&format!("F2D {label} speedup"), ps, *s));
        println!("{}", compare_line(&format!("F2D {label} eff-up"), pe, *e));
    }
    for ((n, s, e), (ps, pe)) in fft_ratios.iter().zip([(3.26, 7.00), (3.88, 1.88), (2.35, 1.27)]) {
        println!("{}", compare_line(&format!("FFT {n} speedup"), ps, *s));
        println!("{}", compare_line(&format!("FFT {n} eff-up"), pe, *e));
    }
    println!("{}", compare_line("MM-T speedup vs CHARM", 1.89, mmt_speed));
    println!("{}", compare_line("MM-T eff-up vs CHARM", 1.51, mmt_eff));

    // the qualitative claims that MUST hold (who wins)
    assert!(mm_speed > 0.9, "EA4RCA MM must be at parity or better with CHARM");
    assert!(f2d_ratios.iter().all(|(_, s, _)| *s > 10.0), "F2D wins by >10x");
    assert!(fft_ratios.iter().all(|(_, s, _)| *s > 1.5), "FFT wins vs CCC2023");
    assert!(mmt_speed > 1.5, "MM-T near-2x CHARM");
    println!("\nall qualitative win/loss relations hold.");
}
