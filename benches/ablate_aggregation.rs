//! Ablation — communication aggregation grain (the Table 2 claim,
//! swept): how single-core 32^3 MM run time varies with the stream
//! grain size, from fully interleaved (16 B) to fully aggregated, vs
//! the DMA phase design. This is the design choice the whole framework
//! rests on (DESIGN.md §7).
//!
//! Run: `cargo bench --bench ablate_aggregation`

use ea4rca::sim::comm::TransferMethod;
use ea4rca::sim::core::{mm_ops, KernelClass, KernelInvocation};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let inv = KernelInvocation::new(KernelClass::F32Mac, mm_ops(32, 32, 32));
    let compute = inv.secs_ideal(&p);
    let bytes = 12_288;

    let mut t = Table::new(
        "Ablation — communication grain vs run time (32^3 MM, single core)",
        &["grain (B)", "interrupts", "run time (us)", "slowdown vs DMA"],
    );
    let dma = compute + TransferMethod::DmaAggregated.secs(&p, bytes);
    let mut prev = f64::INFINITY;
    for grain in [16usize, 64, 256, 1024, 4096, 12288] {
        let total = compute
            + TransferMethod::StreamInterleaved { grain_bytes: grain }.secs(&p, bytes);
        let interrupts = bytes.div_ceil(grain);
        t.row(&[
            grain.to_string(),
            interrupts.to_string(),
            fmt_f(total * 1e6, 2),
            format!("{:.2}x", total / dma),
        ]);
        assert!(total <= prev, "coarser grains must not be slower");
        prev = total;
    }
    t.row(&["DMA".into(), "1".into(), fmt_f(dma * 1e6, 2), "1.00x".into()]);
    t.print();
    println!(
        "\naggregating communication monotonically converges on the DMA phase design — \
         the paper's method(1)->(3) progression, continuously."
    );
}
