//! Ablation — PU placement and inter-PU NoC traffic. The paper (§3.3)
//! advises minimising inter-PU communication; this quantifies why:
//! stream circuits between distant PUs cross shared switches, and hot
//! switches time-share their ports.
//!
//! Run: `cargo bench --bench ablate_placement`

use ea4rca::sim::array::AieArray;
use ea4rca::sim::noc::{region_centre, Noc};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();

    // place the 6 MM PUs as the first-fit placer does
    let mut arr = AieArray::new(&p);
    let placements: Vec<_> = (0..6).map(|_| arr.place(64).unwrap()).collect();
    let centres: Vec<_> = placements.iter().map(|p| region_centre(p.primary())).collect();

    // Scenario A: ring of neighbour circuits (adjacent PUs exchange
    // halo/accumulator data) — the EA4RCA-recommended pattern.
    let mut noc_a = Noc::new(&p);
    let mut ring = Vec::new();
    for i in 0..centres.len() {
        ring.push(noc_a.connect(centres[i], centres[(i + 1) % centres.len()]));
    }

    // Scenario B: all-to-one (every PU streams to PU0) — the pattern the
    // paper warns against.
    let mut noc_b = Noc::new(&p);
    let mut star = Vec::new();
    for c in centres.iter().skip(1) {
        star.push(noc_b.connect(*c, centres[0]));
    }

    let bytes = 65_536; // one 128x128 float quarter-block
    let mut t = Table::new(
        "Ablation — inter-PU NoC patterns (6 MM PUs, 64 KiB per circuit)",
        &["pattern", "circuits", "max hops", "hot-switch load", "worst xfer (us)"],
    );
    let worst_a = ring
        .iter()
        .map(|c| noc_a.transfer_secs(&p, c, bytes))
        .fold(0.0f64, f64::max);
    let worst_b = star
        .iter()
        .map(|c| noc_b.transfer_secs(&p, c, bytes))
        .fold(0.0f64, f64::max);
    t.row(&[
        "neighbour ring".into(),
        ring.len().to_string(),
        ring.iter().map(|c| c.hops).max().unwrap().to_string(),
        noc_a.max_switch_load().to_string(),
        fmt_f(worst_a * 1e6, 2),
    ]);
    t.row(&[
        "all-to-one star".into(),
        star.len().to_string(),
        star.iter().map(|c| c.hops).max().unwrap().to_string(),
        noc_b.max_switch_load().to_string(),
        fmt_f(worst_b * 1e6, 2),
    ]);
    t.print();
    println!(
        "\nthe star pattern's hot switch carries {}x the ring's load and its \
         worst transfer is {:.1}x slower — quantifying §3.3's 'minimise \
         inter-PU communication' rule.",
        noc_b.max_switch_load() / noc_a.max_switch_load().max(1),
        worst_b / worst_a
    );
    assert!(worst_b > worst_a);
}
