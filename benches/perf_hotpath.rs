//! §Perf — the hot paths, measured:
//!
//! * L3 scheduler throughput (simulated engine-iterations per second) on
//!   the Table 6 sweep — this must stay high enough that the full-table
//!   benches run in seconds.
//! * Runtime execution latency per artifact (the serving hot path) on
//!   the active backend, after a warm-up prepare/compile.
//!
//! Run: `cargo bench --bench perf_hotpath`
//! Before/after numbers are recorded in EXPERIMENTS.md §Perf.

use ea4rca::apps::mm;
use ea4rca::runtime::{Runtime, Tensor};
use ea4rca::sim::params::HwParams;
use ea4rca::util::rng::Rng;
use ea4rca::util::stats::bench;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();

    // ---- L3 scheduler throughput ----
    let mut t = Table::new(
        "L3 scheduler hot path",
        &["workload", "engine iters", "wall (ms)", "Miters/s"],
    );
    for size in [1536usize, 6144] {
        let iters = mm::iter_computing_engine(size, size, size, 6);
        let s = bench(1, 5, || {
            let r = mm::run(&p, size, 6, false).unwrap();
            std::hint::black_box(r.time_secs);
        });
        t.row(&[
            format!("MM {size}^3 6PU"),
            iters.to_string(),
            fmt_f(s.mean * 1e3, 2),
            fmt_f(iters as f64 / s.mean / 1e6, 2),
        ]);
    }
    t.print();

    // ---- runtime execution hot path ----
    let Ok(rt) = Runtime::new() else {
        println!("\n(runtime unavailable — skipping the execution hot-path section)");
        return;
    };
    let mut rng = Rng::new(3);
    let mut t = Table::new(
        &format!("execution hot path on {} (after warm-up)", rt.platform()),
        &["artifact", "mean (us)", "p95 (us)", "throughput"],
    );
    let cases: Vec<(&str, Vec<Tensor>, String)> = vec![
        (
            "mm32",
            vec![
                Tensor::f32(&[32, 32], rng.normal_vec(1024)),
                Tensor::f32(&[32, 32], rng.normal_vec(1024)),
            ],
            "32^3 MM".into(),
        ),
        (
            "mm_pu128",
            vec![
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
                Tensor::f32(&[128, 128], rng.normal_vec(128 * 128)),
            ],
            "128^3 MM".into(),
        ),
        (
            "filter2d_pu8",
            vec![
                Tensor::i32(&[8, 36, 36], rng.int_vec_i32(8 * 36 * 36, -128, 127)),
                Tensor::i32(&[5, 5], rng.int_vec_i32(25, -8, 8)),
            ],
            "8 tiles".into(),
        ),
        (
            "fft1024",
            vec![
                Tensor::f32(&[1024], rng.normal_vec(1024)),
                Tensor::f32(&[1024], rng.normal_vec(1024)),
            ],
            "1024-pt FFT".into(),
        ),
    ];
    for (name, inputs, what) in &cases {
        rt.warmup(&[name]).unwrap();
        let s = bench(3, 30, || {
            let out = rt.execute(name, inputs).unwrap();
            std::hint::black_box(out.len());
        });
        t.row(&[
            name.to_string(),
            fmt_f(s.mean * 1e6, 1),
            fmt_f(s.p95 * 1e6, 1),
            format!("{} / {:.1} us", what, s.mean * 1e6),
        ]);
    }
    t.print();
}
