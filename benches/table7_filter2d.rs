//! Table 7 — Filter2D accelerator performance across resolutions and PU
//! quantities (12 rows), with paper anchors.
//!
//! Run: `cargo bench --bench table7_filter2d`

use ea4rca::apps::filter2d;
use ea4rca::report::{compare_line, perf_row, perf_table};
use ea4rca::sim::params::HwParams;

fn main() {
    let p = HwParams::vck5000();
    let mut t = perf_table("Table 7 — Filter2D accelerator (Int32 arithmetic, 5x5)");
    let wall = std::time::Instant::now();
    let scales: [(usize, usize, &str); 4] = [
        (128, 128, "128x128"),
        (3480, 2160, "3480x2160(4K)"),
        (7680, 4320, "7680x4320(8K)"),
        (15360, 8640, "15360x8640(16K)"),
    ];
    for (h, w, label) in scales {
        for (pus, pl) in [(44, "44(100%)"), (20, "20(45%)"), (4, "4(9%)")] {
            let r = filter2d::run(&p, h, w, pus, false).expect("run");
            // the paper divides GOPS/AIE by the *requested* PU cores
            perf_row(&mut t, label, pl, &r, Some(pus * filter2d::CORES_PER_PU));
        }
    }
    t.print();
    println!("(sweep simulated in {:.2} s wall-clock)\n", wall.elapsed().as_secs_f64());

    let r = filter2d::run(&p, 3480, 2160, 44, false).unwrap();
    println!("{}", compare_line("4K 44PU tasks/sec", 2315.94, r.tasks_per_sec));
    println!("{}", compare_line("4K 44PU GOPS", 870.42, r.gops));
    let r = filter2d::run(&p, 15360, 8640, 44, false).unwrap();
    println!("{}", compare_line("16K 44PU time (ms)", 6.32, r.time_secs * 1e3));
    println!("{}", compare_line("16K 44PU GOPS", 1050.43, r.gops));
    let r = filter2d::run(&p, 128, 128, 44, false).unwrap();
    println!("{}", compare_line("128x128 44PU tasks/sec", 6468.72, r.tasks_per_sec));
}
