//! Table 2 — simulation test of the three communication methods on a
//! single-core 32^3 float MM (65 536 FLOP, 12 288 B of traffic):
//! stream+crossover vs stream+aggregation vs DMA+aggregation.
//!
//! Run: `cargo bench --bench table2_methods`

use ea4rca::report::compare_line;
use ea4rca::sim::comm::TransferMethod;
use ea4rca::sim::core::{mm_ops, KernelClass, KernelInvocation};
use ea4rca::sim::params::HwParams;
use ea4rca::util::table::{fmt_f, Table};

fn main() {
    let p = HwParams::vck5000();
    let inv = KernelInvocation::new(KernelClass::F32Mac, mm_ops(32, 32, 32));
    // Table 2 is the paper's *ideal simulation state*: no invocation
    // overhead on the compute side.
    let compute = inv.secs_ideal(&p);
    let bytes = 12_288; // A + B in, C out (float)

    let rows: [(&str, usize, TransferMethod, f64); 3] = [
        ("(1) AIE Stream + Crossover", 16,
         TransferMethod::StreamInterleaved { grain_bytes: 64 }, 31.06),
        ("(2) AIE Stream + Aggregation", 1024,
         TransferMethod::StreamAggregated, 8.61),
        ("(3) AIE DMA + Aggregation", 1024,
         TransferMethod::DmaAggregated, 3.49),
    ];

    let mut t = Table::new(
        "Table 2 — three communication methods, 32^3 float MM, single core",
        &["Method", "Data Type", "Comm size", "Overall FLOP", "Run time (us)", "Paper (us)"],
    );
    for (name, comm_size, method, paper_us) in rows {
        let total = compute + method.secs(&p, bytes);
        t.row(&[
            name.to_string(),
            "Float".into(),
            comm_size.to_string(),
            "65536".into(),
            fmt_f(total * 1e6, 2),
            fmt_f(paper_us, 2),
        ]);
    }
    t.print();

    println!();
    for (name, _, method, paper_us) in rows {
        let total = (compute + method.secs(&p, bytes)) * 1e6;
        println!("{}", compare_line(name, paper_us, total));
    }
}
