//! Figure 2 — the EA4RCA running process: DU-PU pairs alternating
//! computation and communication phases, pipelined and independent
//! across pairs. Rendered as an ASCII timeline from a traced run of the
//! MM accelerator (3 pairs, a few iterations).
//!
//! Run: `cargo bench --bench fig2_pipeline`

use ea4rca::apps::mm;
use ea4rca::coordinator::scheduler::{ExecMode, GroupSpec, SimEngine};
use ea4rca::sim::params::HwParams;
use ea4rca::sim::trace::Phase;

fn main() {
    let p = HwParams::vck5000();
    // three independent DU-PU pairs (1:2 each) to make Fig 2's "pairs in
    // different stages simultaneously" visible
    let groups: Vec<GroupSpec> = (0..3)
        .map(|i| GroupSpec {
            name: format!("pair{i}"),
            du: mm::mm_du(2, 6),
            pu: mm::mm_pu(),
            engine_iters: 6,
mode: ExecMode::Regular,
        })
        .collect();
    let engine = SimEngine::new(p.clone()).with_trace(true);
    let r = engine.run(&groups);

    println!("Figure 2 — DU-PUs pair execution flow (MM, 3 pairs x 2 PUs, 6 iterations)\n");
    let horizon = r.trace.horizon_ps();
    println!("{}", r.trace.render(110, 0, horizon));

    println!("per-lane duty over the run:");
    for g in 0..3 {
        for pu in 0..2 {
            let lane = format!("G{g}.PU{pu}");
            println!(
                "  {lane}: compute {:.0}%  comm {:.0}%",
                r.trace.duty(&lane, Phase::Compute, horizon) * 100.0,
                r.trace.duty(&lane, Phase::Comm, horizon) * 100.0,
            );
        }
    }
    println!(
        "\nphases alternate within a pair and overlap across pairs — the Fig 2 pipeline. \
         makespan {:.1} us, mean compute duty {:.2}",
        r.makespan_secs * 1e6,
        r.compute_duty
    );
}
