//! Runtime probe: exercise every artifact in the manifest on the active
//! backend and check its numerics against the reference oracles — the
//! useful core of the old fftbisect/multidbg debug examples, folded into
//! one assertive probe.
//!
//! Run: `cargo run --release --example runtime_probe`
//! (`EA4RCA_BACKEND=pjrt` to probe the PJRT substrate instead).

use ea4rca::runtime::tensor::{fft_ref, filter2d_ref, matmul_ref};
use ea4rca::runtime::{Runtime, Tensor};
use ea4rca::util::rng::Rng;

fn max_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new()?;
    println!("== runtime probe: {} ==\n", rt.platform());
    let mut rng = Rng::new(0xB15EC7);

    // f32 matmul family: mm32, mm_pu128, mmt_cascade8
    for (name, m, k, n) in
        [("mm32", 32, 32, 32), ("mm_pu128", 128, 128, 128), ("mmt_cascade8", 32, 256, 32)]
    {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let out = rt.execute(
            name,
            &[Tensor::f32(&[m, k], a.clone()), Tensor::f32(&[k, n], b.clone())],
        )?;
        let err = max_err(out[0].as_f32()?, &matmul_ref(&a, &b, m, k, n));
        println!("{name:<14} {m}x{k}x{n}  max |err| = {err:.2e}");
        assert!(err < 5e-3, "{name} numerics off: {err}");
    }

    // cascade stage: mm32_acc
    {
        let a = rng.normal_vec(1024);
        let b = rng.normal_vec(1024);
        let acc = rng.normal_vec(1024);
        let out = rt.execute(
            "mm32_acc",
            &[
                Tensor::f32(&[32, 32], a.clone()),
                Tensor::f32(&[32, 32], b.clone()),
                Tensor::f32(&[32, 32], acc.clone()),
            ],
        )?;
        let mut want = matmul_ref(&a, &b, 32, 32, 32);
        for (w, c) in want.iter_mut().zip(&acc) {
            *w += c;
        }
        let err = max_err(out[0].as_f32()?, &want);
        println!("mm32_acc       32x32x32+acc  max |err| = {err:.2e}");
        assert!(err < 1e-3, "mm32_acc numerics off: {err}");
    }

    // int32 filter: filter2d_pu8 (exact)
    {
        let tiles = rng.int_vec_i32(8 * 36 * 36, -128, 127);
        let kern = rng.int_vec_i32(25, -16, 16);
        let out = rt.execute(
            "filter2d_pu8",
            &[Tensor::i32(&[8, 36, 36], tiles.clone()), Tensor::i32(&[5, 5], kern.clone())],
        )?;
        let got = out[0].as_i32()?;
        for t in 0..8 {
            let want = filter2d_ref(&tiles[t * 36 * 36..(t + 1) * 36 * 36], 36, 36, &kern, 5);
            assert_eq!(&got[t * 1024..(t + 1) * 1024], &want[..], "filter2d tile {t}");
        }
        println!("filter2d_pu8   8x36x36       exact");
    }

    // fft family across every size in the manifest
    for n in [1024usize, 2048, 4096, 8192] {
        let name = format!("fft{n}");
        let re = rng.normal_vec(n);
        let im = rng.normal_vec(n);
        let out = rt.execute(
            &name,
            &[Tensor::f32(&[n], re.clone()), Tensor::f32(&[n], im.clone())],
        )?;
        let (wr, wi) = fft_ref(&re, &im);
        let err = max_err(out[0].as_f32()?, &wr).max(max_err(out[1].as_f32()?, &wi));
        let tol = 1e-2 * (n as f64).sqrt();
        println!("{name:<14} {n}-pt        max |err| = {err:.2e}");
        assert!(err < tol, "{name} numerics off: {err}");
    }

    println!("\nall artifacts OK on this backend");
    Ok(())
}
