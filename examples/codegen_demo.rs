//! AIE Graph Code Generator demo (paper §3.5): parse each accelerator's
//! Graph Configuration File, validate the PU structure, and emit the
//! ADF-style C++ project into `generated/<name>/`.
//!
//! Run: `cargo run --release --example codegen_demo`

use ea4rca::codegen::{config::PuConfig, generator};

fn main() -> anyhow::Result<()> {
    println!("== AIE Graph Code Generator ==\n");
    for name in ["mm", "filter2d", "fft", "mmt"] {
        let path = format!("configs/{name}.json");
        let cfg = PuConfig::from_file(std::path::Path::new(&path))?;
        let proj = generator::generate(&cfg)?;
        let out = std::path::PathBuf::from("generated").join(name);
        proj.write_to(&out)?;
        println!(
            "{path:<22} -> {}/: PU '{}' | {:>3} cores | {:>2} PLIO | x{} copies | {} PST(s)",
            out.display(),
            cfg.name,
            cfg.pu.cores(),
            cfg.pu.total_plios(),
            cfg.copies,
            cfg.pu.psts.len()
        );
        // show a taste of the generated graph
        for line in proj.graph_h.lines().take(6) {
            println!("    | {line}");
        }
        println!();
    }
    println!("one-click generation complete — drop `generated/<app>/` into a Vitis AIE project.");
    Ok(())
}
