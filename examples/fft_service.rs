//! FFT serving scenario: a batch of 1024-point FFT requests served
//! through the FFT PU artifact (real numerics, verified against the
//! oracle), plus the simulated Table 8 rows for the same configuration.
//!
//! Run: `cargo run --release --example fft_service`

use ea4rca::apps::fft;
use ea4rca::report::compare_line;
use ea4rca::runtime::tensor::fft_ref;
use ea4rca::runtime::Runtime;
use ea4rca::sim::params::HwParams;
use ea4rca::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== FFT service: 64 x 1024-pt requests through the PU ==\n");
    let rt = Runtime::new()?;
    rt.warmup(&["fft1024"])?;
    let mut rng = Rng::new(0xFF7);
    let n = 1024;
    let batch = 64;

    let mut worst = 0.0f64;
    let t0 = std::time::Instant::now();
    for _ in 0..batch {
        let re = rng.normal_vec(n);
        let im = rng.normal_vec(n);
        let (or_, oi) = fft::fft_via_pu(&rt, &re, &im)?;
        let (wr, wi) = fft_ref(&re, &im);
        for ((a, b), (c, d)) in or_.iter().zip(&wr).zip(oi.iter().zip(&wi)) {
            worst = worst.max((a - b).abs() as f64).max((c - d).abs() as f64);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {batch} requests in {:.3} s ({:.0} req/s on the CPU substrate), \
         max |err| vs oracle = {worst:.2e}",
        dt,
        batch as f64 / dt
    );
    assert!(worst < 0.5, "fft numerics off: {worst}");

    println!("\nsimulated 1024-pt, 8 PUs (Table 8 row):");
    let p = HwParams::vck5000();
    let r = fft::run(&p, 1024, 8, 4096, false)?.expect("feasible");
    println!("  {}", compare_line("run time (us/task)", 0.43, 1e6 / r.tasks_per_sec));
    println!("  {}", compare_line("tasks/sec", 2_325_581.40, r.tasks_per_sec));
    println!("  {}", compare_line("power (W)", 12.58, r.power_w));
    println!("  {}", compare_line("TPS/W", 184_863.39, r.tasks_per_sec_per_w));

    println!("\ninfeasible configuration check (the paper's N/A cell):");
    match fft::run(&p, 8192, 2, 64, false)? {
        None => println!("  8192-pt on 2 PUs: N/A (exceeds AIE core memory) — matches Table 8"),
        Some(_) => anyhow::bail!("8192/2PU should be infeasible"),
    }
    Ok(())
}
