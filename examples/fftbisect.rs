use ea4rca::runtime::{Runtime, Tensor};
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let n = 16usize;
    let mut re = vec![0.0f32; n]; re[0] = 1.0;
    let im = vec![0.0f32; n];
    let g = rt.execute("gather", &[Tensor::f32(&[n], re.clone())]).unwrap();
    println!("gather: {:?}", &g[0].as_f32().unwrap()[..4]);
    let s = rt.execute("stage1", &[Tensor::f32(&[n], re.clone()), Tensor::f32(&[n], im.clone())]).unwrap();
    println!("stage1: {:?} {:?}", &s[0].as_f32().unwrap()[..4], &s[1].as_f32().unwrap()[..4]);
    let f = rt.execute("fft16", &[Tensor::f32(&[n], re), Tensor::f32(&[n], im)]).unwrap();
    println!("fft16: {:?}", &f[0].as_f32().unwrap()[..4]);
}
