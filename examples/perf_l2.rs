use ea4rca::runtime::{Runtime, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::util::stats::bench;
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let mut rng = Rng::new(1);
    let a = Tensor::f32(&[128,128], rng.normal_vec(128*128));
    let b = Tensor::f32(&[128,128], rng.normal_vec(128*128));
    for name in ["mm_explicit", "mm_grid"] {
        rt.warmup(&[name]).unwrap();
        let s = bench(5, 50, || { rt.execute(name, &[a.clone(), b.clone()]).unwrap(); });
        println!("{name}: mean {:.1} us  p95 {:.1} us", s.mean*1e6, s.p95*1e6);
    }
}
