use ea4rca::runtime::{Runtime, Tensor};
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let n = 16usize;
    let mut re = vec![0.0f32; n]; re[0] = 1.0;
    let im = vec![0.0f32; n];
    for name in ["g2", "g3", "g4"] {
        let s = rt.execute(name, &[Tensor::f32(&[n], re.clone()), Tensor::f32(&[n], im.clone())]).unwrap();
        println!("{name}: {:?}", &s[0].as_f32().unwrap()[..8]);
    }
}
