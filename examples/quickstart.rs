//! Quickstart: deploy the paper's MM accelerator, simulate a 768^3 MM
//! (Table 6's first row), then push a real 256^3 MM through the PJRT
//! runtime and check the numbers against a CPU oracle.
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` once beforehand for the PJRT part).

use ea4rca::api::designs;
use ea4rca::apps::mm;
use ea4rca::runtime::tensor::matmul_ref;
use ea4rca::sim::params::HwParams;
use ea4rca::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let p = HwParams::vck5000();

    // --- 0. the design, described once ---------------------------------
    println!("== EA4RCA quickstart ==\n");
    let design = designs::mm();
    println!(
        "design '{}': kernel {}, {} cores/PU x{} copies -> artifact {}",
        design.name(),
        design.kernel(),
        design.cores(),
        design.copies(),
        design.artifact()
    );
    let pred = design.predict(1);
    println!(
        "cost model (no runtime needed): one PU dispatch predicted at {:.1} us, {:.1} W\n",
        pred.latency_secs * 1e6,
        pred.power_w
    );

    // --- 1. simulate the paper's configuration -------------------------
    println!("simulating 768^3 float MM on the 6-PU / 384-core design:");
    let r = mm::run(&p, 768, 6, false)?;
    println!(
        "  {:.2} ms | {:.0} tasks/s | {:.1} GOPS | {:.2} GOPS/AIE | {:.1} W | {:.1} GOPS/W",
        r.time_secs * 1e3,
        r.tasks_per_sec,
        r.gops,
        r.gops_per_aie,
        r.power_w,
        r.gops_per_w
    );
    println!("  (paper Table 6 row 1: 0.44 ms, 2263 tasks/s, 2050 GOPS, 33.0 W)\n");

    // --- 2. real numerics through the AOT artifacts --------------------
    println!("executing a real 256^3 MM through the mm_pu128 artifact (PJRT):");
    let rt = design.runtime()?;
    let mut rng = Rng::new(42);
    let n = 256;
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    let t0 = std::time::Instant::now();
    let c = mm::matmul_via_pus(&rt, &a, &b, n)?;
    let dt = t0.elapsed().as_secs_f64();
    let want = matmul_ref(&a, &b, n, n, n);
    let err = c
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max);
    println!("  {:.3} s on the CPU substrate, max |err| vs oracle = {err:.2e}", dt);
    assert!(err < 1e-2, "numerics mismatch");
    println!("\nOK — simulation and numerics both check out.");
    Ok(())
}
