use ea4rca::runtime::{Runtime, Tensor};
use ea4rca::util::rng::Rng;
use ea4rca::util::stats::bench;
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let mut rng = Rng::new(1);
    let a = Tensor::f32(&[128,128], rng.normal_vec(128*128));
    let b = Tensor::f32(&[128,128], rng.normal_vec(128*128));
    for name in ["mm_explicit", "mm_grid", "mm_xladot"] {
        rt.warmup(&[name]).unwrap();
        let s = bench(5, 50, || { rt.execute(name, &[a.clone(), b.clone()]).unwrap(); });
        println!("{name}: mean {:.1} us ({:.2} GFLOPS)", s.mean*1e6, 2.0*128f64.powi(3)/s.mean/1e9);
    }
}
