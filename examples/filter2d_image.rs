//! Filter2D scenario: filter a synthetic "sensor frame" through the
//! Parallel<8> PU artifact, verify against the oracle, then report the
//! paper's 4K row from the simulator.
//!
//! Run: `cargo run --release --example filter2d_image`

use ea4rca::apps::filter2d;
use ea4rca::report::compare_line;
use ea4rca::runtime::tensor::filter2d_ref;
use ea4rca::runtime::Runtime;
use ea4rca::sim::params::HwParams;
use ea4rca::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Filter2D: 256x256 frame through the PU artifacts ==\n");
    let (h, w) = (256, 256);
    let mut rng = Rng::new(7);
    // synthetic frame with a gradient + noise (padded with a 4-pixel halo)
    let img: Vec<i32> = (0..(h + 4) * (w + 4))
        .map(|i| {
            let r = (i / (w + 4)) as i32;
            let c = (i % (w + 4)) as i32;
            (r + c) % 251 + rng.range_i64(-20, 20) as i32
        })
        .collect();
    // a 5x5 sharpen-ish kernel
    let mut kern = vec![-1i32; 25];
    kern[12] = 32;

    let rt = Runtime::new()?;
    let t0 = std::time::Instant::now();
    let out = filter2d::filter_image_via_pus(&rt, &img, h, w, &kern)?;
    let dt = t0.elapsed().as_secs_f64();
    let want = filter2d_ref(&img, h + 4, w + 4, &kern, 5);
    assert_eq!(out, want, "int32 filter must be exact");
    println!(
        "filtered {}x{} in {:.3} s via {} PU iterations — exact match vs oracle\n",
        h,
        w,
        dt,
        (h / 32) * (w / 32) / 8
    );

    println!("simulated 4K (3480x2160) frame on the 44-PU design (Table 7):");
    let p = HwParams::vck5000();
    let r = filter2d::run(&p, 3480, 2160, 44, false)?;
    println!("  {}", compare_line("time (ms)", 0.43, r.time_secs * 1e3));
    println!("  {}", compare_line("tasks/sec", 2315.94, r.tasks_per_sec));
    println!("  {}", compare_line("GOPS", 870.42, r.gops));
    println!("  {}", compare_line("power (W)", 28.29, r.power_w));
    Ok(())
}
