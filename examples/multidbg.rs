use ea4rca::runtime::{Runtime, Tensor};
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let out = rt.execute("multi", &[Tensor::f32(&[4], vec![1.,2.,3.,4.])]).unwrap();
    println!("o1={:?} o2={:?}", out[0].as_f32().unwrap(), out[1].as_f32().unwrap());
}
