//! End-to-end driver (DESIGN.md E11): the full system on a real workload.
//!
//! * Deploys the paper's MM accelerator design (codegen-validated PU).
//! * Routes an ENTIRE 768^3 float MM through the PJRT runtime — all 216
//!   PU iterations (6 x 6 x 6 blocks of 128^3), with the DU's task
//!   decomposition and the TPC's K-accumulation running in the rust
//!   coordinator — and validates every output element against a CPU
//!   oracle.
//! * Simulates the same workload on the calibrated VCK5000 model and
//!   reports the paper-vs-measured headline numbers.
//!
//! Run: `cargo run --release --example e2e_mm` (after `make artifacts`).
//! Results are recorded in EXPERIMENTS.md §E11.

use ea4rca::apps::mm;
use ea4rca::codegen::config::PuConfig;
use ea4rca::report::compare_line;
use ea4rca::runtime::tensor::matmul_ref;
use ea4rca::runtime::Runtime;
use ea4rca::sim::params::HwParams;
use ea4rca::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== EA4RCA end-to-end driver: 768^3 float MM ==\n");

    // 0. the deployed design comes from the same config file the code
    //    generator consumes — single source of truth.
    let cfg = PuConfig::from_json_text(include_str!("../configs/mm.json"))?;
    println!(
        "PU from configs/mm.json: {} cores, {} PLIOs, {} copies (validated)\n",
        cfg.pu.cores(),
        cfg.pu.total_plios(),
        cfg.copies
    );
    assert_eq!(cfg.pu.cores(), 64);

    // 1. real numerics: the whole task through PJRT.
    let n = 768;
    let rt = Runtime::new()?;
    rt.warmup(&["mm_pu128"])?;
    let mut rng = Rng::new(0xE2E);
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    println!("executing all {} PU iterations through mm_pu128...", 6 * 6 * 6);
    let t0 = std::time::Instant::now();
    let c = mm::matmul_via_pus(&rt, &a, &b, n)?;
    let exec_secs = t0.elapsed().as_secs_f64();

    println!("validating 768x768 output against the CPU oracle...");
    let want = matmul_ref(&a, &b, n, n, n);
    let mut max_err = 0.0f64;
    for (x, y) in c.iter().zip(&want) {
        max_err = max_err.max((x - y).abs() as f64);
    }
    assert!(max_err < 5e-2, "max err {max_err}");
    let ops = 2.0 * (n as f64).powi(3);
    println!(
        "  done: {exec_secs:.2} s on the CPU substrate ({:.2} GOPS), max |err| = {max_err:.2e}\n",
        ops / exec_secs / 1e9
    );

    // 2. simulated timing on the calibrated VCK5000 model.
    let p = HwParams::vck5000();
    println!("simulated on the calibrated VCK5000 model (6 PUs):");
    let r = mm::run(&p, n, 6, false)?;
    println!("  {}", compare_line("time (ms)", 0.44, r.time_secs * 1e3));
    println!("  {}", compare_line("tasks/sec", 2263.35, r.tasks_per_sec));
    println!("  {}", compare_line("GOPS", 2050.53, r.gops));
    println!("  {}", compare_line("GOPS/AIE", 5.34, r.gops_per_aie));
    println!("  {}", compare_line("power (W)", 33.02, r.power_w));
    println!("  {}", compare_line("GOPS/W", 62.10, r.gops_per_w));

    let stats = rt.stats();
    let s = &stats["mm_pu128"];
    println!(
        "\nPJRT hot path: {} executions, mean {:.3} ms each (compile {:.2} s, once)",
        s.executions,
        s.total_exec_secs / s.executions as f64 * 1e3,
        s.compile_secs
    );
    println!("\nE2E OK — all layers compose: config -> PU -> PJRT numerics -> sim report.");
    Ok(())
}
