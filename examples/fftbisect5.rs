use ea4rca::runtime::{Runtime, Tensor};
fn main() {
    let rt = Runtime::with_dir("/tmp").unwrap();
    let n = 16usize;
    let mut re = vec![0.0f32; n]; re[0] = 1.0;
    let im = vec![0.0f32; n];
    let s = rt.execute("trfull", &[Tensor::f32(&[n], re.clone()), Tensor::f32(&[n], im.clone())]).unwrap();
    println!("trfull impulse: {:?}", &s[0].as_f32().unwrap()[..8]);
}
