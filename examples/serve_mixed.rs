//! Serving scenario: the micro-batched leader/worker coordinator
//! serving a mixed stream of MM / FFT / Filter2D requests through
//! per-worker runtimes — admission queue in front, same-artifact
//! micro-batches to the least-loaded worker, latency reported with its
//! queue-vs-exec split.
//!
//! Run: `cargo run --release --example serve_mixed`

use ea4rca::api::{designs, DeployOptions, Deployment};
use ea4rca::util::stats::summarize;
use ea4rca::workload::{generate_stream, Mix};

fn main() -> anyhow::Result<()> {
    println!("== EA4RCA serving: mixed request stream ==\n");
    let n_jobs = 256;
    // the design catalogue deploys as one fleet: per-worker runtimes,
    // every design's artifact warmed, micro-batching on
    let deployment = Deployment::start(
        &designs::catalogue(),
        &DeployOptions { workers: 4, ..DeployOptions::default() },
    )?;
    println!(
        "{} workers up serving {} (per-worker runtimes, warm executables)",
        deployment.workers(),
        deployment.artifacts().join(", ")
    );

    let stream = generate_stream(&Mix::mm_heavy(), n_jobs, 0x5E12);

    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_jobs);
    for (kind, inputs) in stream {
        pending.push(deployment.submit_to(kind.artifact(), inputs)?);
    }
    let results = pending
        .into_iter()
        .map(|p| p.wait())
        .collect::<anyhow::Result<Vec<_>>>()?;
    let wall = t0.elapsed().as_secs_f64();
    let latency =
        summarize(&results.iter().map(|r| r.latency_secs()).collect::<Vec<_>>());

    let errors = results.iter().filter(|r| r.outputs.is_err()).count();
    println!(
        "\nserved {n_jobs} jobs in {:.2} s -> {:.0} jobs/s, {errors} errors",
        wall,
        n_jobs as f64 / wall
    );
    println!(
        "latency: mean {:.2} ms | p50 {:.2} ms | p95 {:.2} ms | max {:.2} ms",
        latency.mean * 1e3,
        latency.p50 * 1e3,
        latency.p95 * 1e3,
        latency.max * 1e3
    );
    let queue = summarize(&results.iter().map(|r| r.queue_secs).collect::<Vec<_>>());
    let exec = summarize(&results.iter().map(|r| r.exec_secs).collect::<Vec<_>>());
    println!(
        "  split: queue mean {:.2} ms (p95 {:.2}) | exec mean {:.3} ms (p95 {:.3})",
        queue.mean * 1e3,
        queue.p95 * 1e3,
        exec.mean * 1e3,
        exec.p95 * 1e3
    );

    let report = deployment.shutdown()?;
    println!("\nmicro-batches ({} dispatched):", report.batches);
    for (artifact, hist) in &report.batch_hist {
        let sizes: Vec<String> =
            hist.iter().map(|(size, count)| format!("{size}x{count}")).collect();
        println!(
            "  {artifact:<16} mean batch {:.2} [{}]",
            report.mean_batch_size(artifact).unwrap_or(0.0),
            sizes.join(" ")
        );
    }
    println!("\nper-worker:");
    for w in &report.workers {
        println!(
            "  worker {}: {} jobs in {} batches, {:.1} ms exec total, {} errors",
            w.worker,
            w.jobs,
            w.batches,
            w.exec_secs * 1e3,
            w.errors
        );
    }
    anyhow::ensure!(errors == 0, "serving errors");
    anyhow::ensure!(
        report.completed_jobs() == n_jobs as u64,
        "job conservation violated"
    );
    let min = report.workers.iter().map(|w| w.jobs).min().unwrap();
    anyhow::ensure!(min > 0, "a worker sat idle");
    println!(
        "\nserving OK — {} micro-batches over {} workers, every job accounted for.",
        report.batches,
        report.workers.len()
    );
    Ok(())
}
