//! Serving scenario: the leader/worker coordinator serving a mixed
//! stream of MM / FFT / Filter2D requests through per-worker PJRT
//! runtimes, reporting latency percentiles and per-worker throughput.
//!
//! Run: `cargo run --release --example serve_mixed`

use ea4rca::coordinator::server::{serve_batch, Server};
use ea4rca::workload::{generate_stream, Mix};

fn main() -> anyhow::Result<()> {
    println!("== EA4RCA serving: mixed request stream ==\n");
    let workers = 4;
    let n_jobs = 256;
    let mut server = Server::start(
        workers,
        ea4rca::runtime::Manifest::default_dir(),
        &["mm_pu128", "fft1024", "filter2d_pu8"],
    )?;
    println!("{} workers up (per-worker PJRT runtimes, warm executables)", server.workers());

    let stream = generate_stream(&Mix::mm_heavy(), n_jobs, 0x5E12);
    let jobs: Vec<(String, Vec<_>)> = stream
        .into_iter()
        .map(|(k, inputs)| (k.artifact().to_string(), inputs))
        .collect();

    let t0 = std::time::Instant::now();
    let (results, latency) = serve_batch(&mut server, jobs)?;
    let wall = t0.elapsed().as_secs_f64();

    let errors = results.iter().filter(|r| r.outputs.is_err()).count();
    println!(
        "\nserved {n_jobs} jobs in {:.2} s -> {:.0} jobs/s, {errors} errors",
        wall,
        n_jobs as f64 / wall
    );
    println!(
        "latency: mean {:.2} ms | p50 {:.2} ms | p95 {:.2} ms | max {:.2} ms",
        latency.mean * 1e3,
        latency.p50 * 1e3,
        latency.p95 * 1e3,
        latency.max * 1e3
    );

    let report = server.shutdown()?;
    println!("\nper-worker:");
    for w in &report.workers {
        println!(
            "  worker {}: {} jobs, {:.1} ms exec total, {} errors",
            w.worker,
            w.jobs,
            w.exec_secs * 1e3,
            w.errors
        );
    }
    anyhow::ensure!(errors == 0, "serving errors");
    let min = report.workers.iter().map(|w| w.jobs).min().unwrap();
    anyhow::ensure!(min > 0, "a worker sat idle");
    println!("\nserving OK — leader routed work across all {} workers.", report.workers.len());
    Ok(())
}
