//! Compile-time facade of the `xla` crate (xla-rs) API surface the
//! `pjrt` runtime backend uses.
//!
//! This workspace builds hermetically — the real xla-rs crate needs a
//! native `xla_extension` shared library that is not part of the image —
//! so this facade keeps the `pjrt` feature *compile-checked* everywhere:
//! `cargo check --features pjrt` exercises the whole backend against
//! these exact signatures. At runtime every PJRT entry point returns a
//! readable error from [`PjRtClient::cpu`], long before any artifact is
//! touched.
//!
//! To run the real thing, replace this path dependency with xla-rs
//! (<https://github.com/LaurentMazare/xla-rs>, the same `xla = "0.1.6"`
//! API) in the root `Cargo.toml` — no source changes needed in the
//! `ea4rca` crate.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type mirroring `xla::Error` closely enough for `?` and
/// `.context(...)` call sites.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the native XLA/PJRT runtime is not linked into this build \
         (the in-tree vendor/xla facade only compile-checks the backend). \
         Swap vendor/xla for the real xla-rs crate to execute HLO artifacts, \
         or use the default interpreter backend (unset EA4RCA_BACKEND)."
    ))
}

/// Element types a [`Literal`] can hold on this substrate (f32/i32 are
/// the only dtypes the artifacts use).
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<Vec<Self>>;
}

/// Backing store for literal data.
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Storage {
        Storage::F32(data)
    }
    fn unwrap(storage: &Storage) -> Option<Vec<f32>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            Storage::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Storage {
        Storage::I32(data)
    }
    fn unwrap(storage: &Storage) -> Option<Vec<i32>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            Storage::F32(_) => None,
        }
    }
}

/// Host-side literal: flat data plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::wrap(data.to_vec()),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy the data out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    /// Decompose a tuple literal. The facade never produces tuples
    /// (execution is unavailable), so this is always an error here.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled executable (never actually constructed by the facade).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client. [`PjRtClient::cpu`] is the single runtime gate: it
/// fails fast with instructions, so callers never get half-way into an
/// execution before discovering the native library is absent.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "facade".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_readably() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("vendor/xla"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
