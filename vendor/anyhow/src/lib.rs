//! In-tree stand-in for the `anyhow` crate.
//!
//! The build is hermetic (no registry access), so the subset of the
//! `anyhow` API this workspace uses is reimplemented here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error values carry their full context
//! chain as rendered strings plus the original error value for
//! [`Error::downcast_ref`] (used by the CLI to pick exit codes).
//!
//! Semantics match upstream where it matters to callers:
//! * `Display` prints the outermost message only.
//! * `{:#}` (alternate) prints the whole chain joined by `": "`.
//! * `Debug` prints the outermost message plus a `Caused by:` list.
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::any::Any;
use std::fmt;

/// `Result<T, anyhow::Error>`, with an overridable error type like
/// upstream's.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. Messages are stored outermost-first.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Build an error from a concrete error value, keeping it for
    /// `downcast_ref` and flattening its `source()` chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Reference to the original error value, if it is a `T`.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }

    /// The context chain, outermost first (at least one entry).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for msg in &self.chain[1..] {
                write!(f, ": {msg}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return Err($crate::anyhow!($fmt, $($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing thing");
    }

    #[test]
    fn downcast_ref_recovers_payload() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // context wrapping keeps the payload
        let e = Err::<(), _>(io_err()).context("ctx").unwrap_err();
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn b() -> Result<()> {
            bail!("bad {}", 42)
        }
        assert_eq!(b().unwrap_err().to_string(), "bad 42");
        fn e(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(e(3).unwrap(), 3);
        assert_eq!(e(30).unwrap_err().to_string(), "x too big: 30");
        let err = anyhow!("plain");
        assert_eq!(err.to_string(), "plain");
    }
}
